"""Meaning preservation (paper Theorem A.1, validated empirically): every
benchmark program compiled to bulk JAX equals the sequential interpreter."""
import numpy as np
import pytest

from repro.core import compile_program, interpret
from repro.core.programs import ALL

rng = np.random.default_rng(42)


def data_for(name):
    n, m, l, K, nv = 8, 6, 5, 4, 10
    if name == "average":
        return dict(V=rng.standard_normal(20), s=0.0, cnt=0.0, avg=0.0)
    if name == "count":
        return dict(V=rng.standard_normal(20), cnt=0.0)
    if name == "conditional_count":
        return dict(V=rng.standard_normal(20), cnt=0.0, limit=0.3)
    if name == "conditional_sum":
        return dict(V=rng.standard_normal(20), s=0.0, limit=0.3)
    if name == "equal":
        w = rng.integers(0, 3, 25).astype(np.float64)
        return dict(W=w, first=float(w[0]), diffs=0.0)
    if name == "string_match":
        return dict(W=rng.integers(0, 9, 30).astype(np.float64),
                    k1=1.0, k2=5.0, k3=11.0, found=np.zeros(3))
    if name == "word_count":
        return dict(W=rng.integers(0, nv, 50).astype(np.float64),
                    C=np.zeros(nv))
    if name == "histogram":
        return dict(P=tuple(rng.integers(0, nv, 40).astype(np.float64)
                            for _ in range(3)),
                    R=np.zeros(nv), G=np.zeros(nv), B=np.zeros(nv))
    if name == "group_by":
        return dict(S=(rng.integers(0, nv, 40).astype(np.float64),
                       rng.standard_normal(40)), C=np.zeros(nv))
    if name == "linear_regression":
        x = rng.standard_normal(30)
        y = 2 * x + 1 + 0.1 * rng.standard_normal(30)
        return dict(P=(x, y), n=30, sum_x=0.0, sum_y=0.0, x_bar=0.0,
                    y_bar=0.0, xx_bar=0.0, xy_bar=0.0, slope=0.0,
                    intercept=0.0)
    if name == "matrix_addition":
        return dict(M=rng.standard_normal((n, m)),
                    N=rng.standard_normal((n, m)), R=np.zeros((n, m)),
                    n=n, m=m)
    if name == "matrix_multiplication":
        return dict(M=rng.standard_normal((n, l)),
                    N=rng.standard_normal((l, m)), R=np.zeros((n, m)),
                    n=n, m=m, l=l)
    if name == "pagerank":
        ne, N = 30, 10
        return dict(E=(rng.integers(0, N, ne).astype(np.float64),
                       rng.integers(0, N, ne).astype(np.float64)),
                    P=np.full(N, 1.0 / N), NP=np.zeros(N), C=np.zeros(N),
                    N=N, num_steps=3.0, steps=0.0, b=0.85)
    if name == "kmeans_step":
        npts = 20
        return dict(P=(rng.standard_normal(npts) * 3,
                       rng.standard_normal(npts) * 3),
                    CX=rng.standard_normal(K), CY=rng.standard_normal(K),
                    K=K, D=np.zeros((npts, K)), MinD=np.full(npts, 1e30),
                    Cl=np.zeros(npts), SX=np.zeros(K), SY=np.zeros(K),
                    CN=np.zeros(K), NX=np.zeros(K), NY=np.zeros(K))
    if name == "matrix_factorization_step":
        return dict(R=rng.standard_normal((n, m)),
                    P=rng.standard_normal((n, l)) * 0.1,
                    Q=rng.standard_normal((l, m)) * 0.1,
                    Pp=rng.standard_normal((n, l)) * 0.1,
                    Qp=rng.standard_normal((l, m)) * 0.1,
                    pq=np.zeros((n, m)), err=np.zeros((n, m)),
                    n=n, m=m, l=l, a=0.002, lam=0.02)
    raise KeyError(name)


def _np64(ins):
    return {k: (np.array(v, dtype=np.float64) if isinstance(v, np.ndarray)
                else v) for k, v in ins.items()}


@pytest.mark.parametrize("name", sorted(ALL))
def test_compiled_equals_interpreter(name):
    fn = ALL[name]
    ins = data_for(name)
    out = compile_program(fn).run(ins)
    ref = interpret(fn.program, _np64(ins))
    for k in out:
        a = np.asarray(out[k], np.float64)
        b = np.asarray(ref[k], np.float64)
        np.testing.assert_allclose(a, b, rtol=2e-3, atol=1e-4, err_msg=k)


@pytest.mark.parametrize("name", ["matrix_multiplication",
                                  "matrix_factorization_step"])
def test_paper_faithful_no_einsum_path(name):
    """optimize_contractions=False = the paper-faithful gather+reduce plan."""
    fn = ALL[name]
    ins = data_for(name)
    a = compile_program(fn, optimize_contractions=True).run(ins)
    b = compile_program(fn, optimize_contractions=False).run(ins)
    for k in a:
        np.testing.assert_allclose(np.asarray(a[k]), np.asarray(b[k]),
                                   rtol=2e-3, atol=1e-4)


def test_jit_compatible():
    import jax
    import jax.numpy as jnp
    fn = ALL["word_count"]
    cp = compile_program(fn)

    @jax.jit
    def run(w):
        return cp.run(dict(W=(w,), C=jnp.zeros(10)))["C"]

    w = jnp.asarray(rng.integers(0, 10, 64), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(run(w)),
        np.asarray(cp.run(dict(W=(np.asarray(w),), C=np.zeros(10)))["C"]),
        rtol=1e-6)

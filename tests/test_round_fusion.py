"""Round fusion (pass 11, DESIGN.md §9): adjacent shard-mappable nodes
group into FusedRound regions, a fully-fusable SeqLoop body becomes ONE
shard_map program with the collectives inside it and the loop running as an
on-device lax.while_loop (zero per-iteration host syncs) — golden-tested
via explain_rounds().  Distributed execution must equal single-device in
all of: fused rounds, the per-member fallback, the replicated-body
on-device loop, and with round fusion disabled.
"""
import os
import subprocess
import sys

import pytest

from repro.core import compile_program
from repro.core.plan import FusedRound, SeqLoop, flatten
from repro.core.programs import ALL

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------------------
# fast: plan-structure goldens
# ---------------------------------------------------------------------------

def test_seq_loop_body_becomes_one_region():
    cp = compile_program(ALL["pagerank"])
    loop = next(n for n in cp.plan if isinstance(n, SeqLoop))
    assert len(loop.body) == 1 and isinstance(loop.body[0], FusedRound)
    assert len(loop.body[0].parts) == 4   # steps, NP:=0, NP⊕, P:=
    assert "FusedRound{4 members}" in cp.explain()


def test_top_level_adjacent_rounds_group():
    cp = compile_program(ALL["kmeans_step"])
    assert len(cp.plan) == 1 and isinstance(cp.plan[0], FusedRound)
    # flattening recovers the ungrouped member order
    assert len(flatten(cp.plan)) == len(cp.plan[0].parts)


def test_round_fusion_off_keeps_plan_flat():
    cp = compile_program(ALL["pagerank"], round_fusion=False)
    assert not any(isinstance(n, FusedRound) for n in flatten(cp.plan))
    loop = next(n for n in cp.plan if isinstance(n, SeqLoop))
    assert not any(isinstance(n, FusedRound) for n in loop.body)


def test_single_member_blocks_not_wrapped():
    # histogram is one Fused node: nothing to group at the top level
    cp = compile_program(ALL["histogram"])
    assert not any(isinstance(n, FusedRound) for n in cp.plan)


def test_fusion_preserves_results_single_device():
    import numpy as np
    from test_core_programs import data_for
    for name in ("pagerank", "kmeans_step", "matrix_multiplication"):
        ins = data_for(name)
        a = compile_program(ALL[name]).run(dict(ins))
        b = compile_program(ALL[name], round_fusion=False).run(dict(ins))
        for k in a:
            np.testing.assert_allclose(np.asarray(a[k], np.float64),
                                       np.asarray(b[k], np.float64),
                                       rtol=1e-5, atol=1e-6,
                                       err_msg=(name, k))


# ---------------------------------------------------------------------------
# slow: distributed golden + equality (subprocess: forces host devices)
# ---------------------------------------------------------------------------

_DIST_CODE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys; sys.path.insert(0, "src")
import numpy as np
from repro.core import compile_program
from repro.core.distributed import compile_distributed
from repro.core.programs import ALL
from repro.launch.mesh import make_test_mesh

mesh = make_test_mesh((8,), ("data",))
rng = np.random.default_rng(5)


def check(dp, single, ins):
    dist = dp.run(ins)
    for k in single:
        a = np.asarray(dist[k], np.float64)
        b = np.asarray(single[k], np.float64)
        assert a.shape == b.shape, (k, a.shape, b.shape)
        err = np.max(np.abs(a - b) / (np.abs(b) + 1.0))
        assert err < 1e-4, (k, err)
    return dp.explain_rounds()


# ---- pagerank: the whole loop is ONE shard_map program with an on-device
# while loop; N=13 not divisible by 8 exercises pad+mask inside it ----
N = 13
ins = dict(E=(rng.integers(0, N, 64).astype(np.float64),
              rng.integers(0, N, 64).astype(np.float64)),
           P=np.full(N, 1 / N), NP=np.zeros(N), C=np.zeros(N),
           N=N, num_steps=3.0, steps=0.0, b=0.85)
single = compile_program(ALL["pagerank"]).run(ins)
dp = compile_distributed(ALL["pagerank"], mesh, ("data",))
text = check(dp, single, ins)
# ISSUE 5 acceptance golden: fused region + on-device loop, 0 host syncs
assert "FusedRound{4 members}" in text, text
assert "on-device lax.while_loop inside ONE fused shard_map round " \\
       "(0 host syncs)" in text, text
assert "fused round: 4 members, 1 shard_map program; on-device " \\
       "lax.while_loop (0 host syncs)" in text, text
assert "reduce(psum_scatter[cost])→NP" in text, text   # collective INSIDE
assert "all_gather: P" in text, text                   # gather INSIDE
assert "host-driven" not in text, text
# second run with identical shapes: the fused program comes from the cache
dp.run(ins)
assert "round cache: 2 traced, 2 hits" in dp.explain_rounds(), \\
    dp.explain_rounds()

# ---- kmeans: the whole step is ONE fused top-level region ----
npts = 24
km = dict(P=(rng.standard_normal(npts) * 3, rng.standard_normal(npts) * 3),
          CX=rng.standard_normal(4), CY=rng.standard_normal(4), K=4,
          D=np.zeros((npts, 4)), MinD=np.full(npts, 1e30),
          Cl=np.zeros(npts), SX=np.zeros(4), SY=np.zeros(4),
          CN=np.zeros(4), NX=np.zeros(4), NY=np.zeros(4))
single = compile_program(ALL["kmeans_step"]).run(km)
dp = compile_distributed(ALL["kmeans_step"], mesh, ("data",))
text = check(dp, single, km)
assert "fused round:" in text and "1 shard_map program" in text, text

# ---- REP-everything fallback: the fused-loop guard fails (stores not
# aligned), the host-driven loop + per-member rounds take over ----
dp_rep = compile_distributed(ALL["pagerank"], mesh, ("data",),
                             shard_dense=False)
single = compile_program(ALL["pagerank"]).run(ins)
text = check(dp_rep, single, ins)
assert "host-driven" in text, text
assert "on-device" not in text, text

# ---- round_fusion=False: per-node rounds, same results ----
cp_off = compile_program(ALL["pagerank"], round_fusion=False)
dp_off = compile_distributed(cp_off, mesh, ("data",))
text = check(dp_off, single, ins)
assert "FusedRound" not in text, text
print("ROUND_FUSION_OK")
"""


@pytest.mark.slow
def test_fused_rounds_distributed():
    """ISSUE 5 acceptance: a distributed SeqLoop executes as ONE shard_map
    program with an on-device lax.while_loop and zero host syncs (golden
    explain_rounds), matching single-device results; fallbacks preserved."""
    r = subprocess.run([sys.executable, "-c", _DIST_CODE],
                       capture_output=True, text=True, cwd=_ROOT,
                       timeout=900)
    assert r.returncode == 0, r.stdout[-3000:] + r.stderr[-3000:]
    assert "ROUND_FUSION_OK" in r.stdout


_REPLICATED_LOOP_CODE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys; sys.path.insert(0, "src")
import numpy as np
from repro.core import compile_program, loop_program
from repro.core import dim, matrix, scalar, vector
from repro.core.distributed import compile_distributed
from repro.launch.mesh import make_test_mesh


@loop_program
def power_iter(M: matrix, v: vector, w: vector, n: dim,
               steps: scalar, k: scalar):
    while steps < k:
        steps += 1.0
        for i in range(0, n):
            w[i] = 0.0
        for i in range(0, n):
            for j in range(0, n):
                w[i] += M[i, j] * v[j]
        for i in range(0, n):
            v[i] = w[i] / n


mesh = make_test_mesh((8,), ("data",))
rng = np.random.default_rng(9)
n = 16
ins = dict(M=rng.standard_normal((n, n)) * 0.1,
           v=np.full(n, 1.0 / n), w=np.zeros(n), n=n, steps=0.0, k=3.0)
single = compile_program(power_iter).run(ins)

# sharded: the loop fuses on-device (aligned stores + einsum members)
dp = compile_distributed(power_iter, mesh, ("data",))
dist = dp.run(ins)
for key in single:
    err = np.max(np.abs(np.asarray(dist[key], np.float64)
                        - np.asarray(single[key], np.float64)))
    assert err < 1e-4, (key, err)
text = dp.explain_rounds()
assert "on-device lax.while_loop inside ONE fused shard_map round" in text, \\
    text

# REP-everything: every body member classifies replicated — the loop must
# run as ONE single-device lax.while_loop, NOT a host-driven loop with a
# blocking condition sync per iteration (the old behaviour)
dp_rep = compile_distributed(power_iter, mesh, ("data",),
                             shard_dense=False)
dist = dp_rep.run(ins)
for key in single:
    err = np.max(np.abs(np.asarray(dist[key], np.float64)
                        - np.asarray(single[key], np.float64)))
    assert err < 1e-4, ("rep", key, err)
text = dp_rep.explain_rounds()
assert "on-device lax.while_loop (replicated body, 0 host syncs)" in text, \\
    text
assert "host-driven" not in text, text
print("REPLICATED_LOOP_OK")
"""


@pytest.mark.slow
def test_replicated_body_loop_runs_on_device(tmp_path):
    """Satellite: a SeqLoop whose body is fully replicated routes through
    the single-device lax.while_loop instead of paying a host condition
    sync every iteration."""
    script = tmp_path / "replicated_loop.py"     # @loop_program needs a file
    script.write_text(_REPLICATED_LOOP_CODE)
    r = subprocess.run([sys.executable, str(script)], capture_output=True,
                       text=True, cwd=_ROOT, timeout=900)
    assert r.returncode == 0, r.stdout[-3000:] + r.stderr[-3000:]
    assert "REPLICATED_LOOP_OK" in r.stdout

"""Serving example: batched prefill + token-by-token decode with a KV
cache, over any of the 10 architectures.

  PYTHONPATH=src python examples/serve_lm.py --arch recurrentgemma-2b
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse

from repro.launch.serve import main as serve_main

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    args = ap.parse_args()
    serve_main(["--arch", args.arch, "--smoke", "--batch", "4",
                "--prompt-len", "16", "--gen", "8"])

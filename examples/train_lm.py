"""End-to-end training driver: data pipeline -> jitted train step -> async
checkpoints -> fault-tolerant resume.

CPU quick demo (~1 minute):
  PYTHONPATH=src python examples/train_lm.py

~100M-parameter preset (a few hundred steps; sized for real accelerators):
  PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse

from repro.launch.train import main as train_main

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=["tiny", "100m"], default="tiny")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--ckpt", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    argv = ["--arch", "llama3-8b", "--steps", str(args.steps),
            "--ckpt", args.ckpt, "--ckpt-every", "10"]
    if args.preset == "tiny":
        argv += ["--smoke", "--global-batch", "8", "--seq", "32"]
    else:  # ~100M params: 12 x d768 llama-style
        argv += ["--d-model", "768", "--layers", "12",
                 "--global-batch", "16", "--seq", "512"]
    if args.resume:
        argv += ["--resume"]
    train_main(argv)

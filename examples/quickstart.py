"""Quickstart: write an imperative array loop, let DIABLO-JAX translate it
to a bulk data-parallel program.

  PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import (RejectionError, compile_program, dim, loop_program,
                        map_, matrix, vector)


# --- the paper's running example: loop-based matrix multiplication -------
@loop_program
def matmul(M: matrix, N: matrix, R: matrix, n: dim, m: dim, l: dim):
    for i in range(0, n):
        for j in range(0, m):
            R[i, j] = 0.0
            for k in range(0, l):
                R[i, j] += M[i, k] * N[k, j]


# --- the paper's intro example: indirect group-by  C[K[i]] += V[i] -------
@loop_program
def grouped_sum(K: vector, V: vector, C: map_, n: dim):
    for i in range(0, n):
        C[int(K[i])] += V[i]


def main():
    print("== source (parsed loop language) ==")
    print(matmul.program.pretty())
    cp = compile_program(matmul)
    print("\n== translated target (monoid comprehensions, paper Fig. 2) ==")
    print(cp.pretty_target())

    rng = np.random.default_rng(0)
    n = 64
    M, N = rng.standard_normal((n, n)), rng.standard_normal((n, n))
    out = cp.run(dict(M=M, N=N, R=np.zeros((n, n)), n=n, m=n, l=n))
    err = np.abs(np.asarray(out["R"]) - M @ N).max()
    print(f"\nmatmul vs numpy max err: {err:.2e} "
          f"(lowered to a single jnp.einsum — contraction recognition)")

    cp2 = compile_program(grouped_sum)
    print("\n== grouped sum target ==")
    print(cp2.pretty_target())
    k = rng.integers(0, 8, 100).astype(np.float64)
    v = rng.standard_normal(100)
    got = np.asarray(cp2.run(dict(K=k, V=v, C=np.zeros(8), n=100))["C"])
    want = np.zeros(8)
    np.add.at(want, k.astype(int), v)
    print("grouped sum max err:", np.abs(got - want).max())

    print("\n== rejection (paper §3.2 recurrence) ==")
    try:
        def smoothing(V: vector, n: dim):
            for i in range(1, n - 1):
                V[i] = (V[i - 1] + V[i + 1]) / 2.0
        from repro.core import parse_program
        compile_program(parse_program(smoothing))
    except RejectionError as e:
        print("rejected as expected:", e)


if __name__ == "__main__":
    main()

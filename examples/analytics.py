"""Distributed data-analytics example: PageRank and KMeans written as
imperative loops, compiled by DIABLO-JAX, and executed over an 8-device
mesh with the paper's operator mapping — sharded bags AND, via the
distribution-analysis pass (DESIGN.md §6), sharded dense arrays: the rank
vectors are ONED_ROW row blocks, not replicas.

  PYTHONPATH=src python examples/analytics.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import compile_program
from repro.core.dist_analysis import Dist
from repro.core.distributed import compile_distributed
from repro.core.programs import kmeans_step, pagerank
from repro.launch.mesh import make_test_mesh


def main():
    mesh = make_test_mesh((8,), ("data",))
    rng = np.random.default_rng(0)

    # ---- PageRank over a random graph ----
    nvert, nedge = 1000, 8000
    E = (rng.integers(0, nvert, nedge).astype(np.float64),
         rng.integers(0, nvert, nedge).astype(np.float64))
    ins = dict(E=E, P=np.full(nvert, 1 / nvert), NP=np.zeros(nvert),
               C=np.zeros(nvert), N=nvert, num_steps=5.0, steps=0.0, b=0.85)
    cp = compile_program(pagerank)
    # memory admission (DESIGN.md §12): the estimator prices the plan's
    # peak device bytes BEFORE anything touches the device — over a
    # budget, run() streams the edge bag in tiles instead of OOM-ing
    est = cp.estimate_memory(ins)
    print(est.summary(None))
    print(cp.explain())        # operator + inferred sharding per statement
    sharded = [a for a, d in cp.dists.items() if d >= Dist.ONED_ROW]
    print(f"\ndense arrays sharded (not replicated): {sorted(sharded)}\n")
    dp = compile_distributed(cp, mesh, ("data",), mode="shardmap")
    ranks = np.asarray(dp.run(ins)["P"])
    single = np.asarray(cp.run(ins)["P"])
    # the operator-selection subsystem (DESIGN.md §8) resolved each
    # group-by's backend at trace time; after a run, explain() carries a
    # `selected:` line per reduce node — surface just those decisions
    print("trace-time decisions per node (op_select backends for the "
          "group-bys,\nfast-path materializations for the stores):")
    for line in cp.explain().splitlines():
        if "selected:" in line:
            print("  " + line.strip())
    print()
    print(f"pagerank: top vertex {ranks.argmax()} rank={ranks.max():.5f} "
          f"(dist vs single max err {np.abs(ranks - single).max():.2e})")
    # REP-everything fallback: same result, replicated placement
    rep = np.asarray(compile_distributed(cp, mesh, ("data",),
                                         shard_dense=False).run(ins)["P"])
    print(f"          REP fallback max err {np.abs(rep - single).max():.2e}")

    # ---- one KMeans step on 2-D points ----
    npts, K = 4000, 8
    ins = dict(P=(rng.standard_normal(npts) * 3, rng.standard_normal(npts) * 3),
               CX=rng.standard_normal(K), CY=rng.standard_normal(K), K=K,
               D=np.zeros((npts, K)), MinD=np.full(npts, 1e30),
               Cl=np.zeros(npts), SX=np.zeros(K), SY=np.zeros(K),
               CN=np.zeros(K), NX=np.zeros(K), NY=np.zeros(K))
    ck = compile_distributed(kmeans_step, mesh, ("data",), mode="gspmd")
    print(ck.cp.estimate_memory(ins).summary(None))
    out = ck.run(ins)
    print("kmeans new centroids x:",
          np.round(np.asarray(out["NX"]), 3).tolist())


if __name__ == "__main__":
    main()

"""Serving compiled loop programs to concurrent clients (DESIGN.md §10).

One PlanServer hosts the mixed pagerank + group_by + kmeans workload; a
background pump thread batches whatever the (asyncio-simulated) clients
throw at it — ragged shapes bucket by compile-cache signature, pad, and
coalesce into vmapped whole-program calls.

  PYTHONPATH=src python examples/serve_plans.py
"""
import asyncio
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import compile_program
from repro.core.programs import group_by, kmeans_step, pagerank
from repro.serve import PlanServer

rng = np.random.default_rng(0)


def request_for(i: int) -> tuple:
    """Client i's request: program and bag length vary per client, so the
    server sees genuinely ragged concurrent traffic."""
    kind = i % 3
    if kind == 0:
        N, ne = 64, 200 + 8 * (i % 5)
        return "pagerank", dict(
            E=(rng.integers(0, N, ne).astype(np.float64),
               rng.integers(0, N, ne).astype(np.float64)),
            P=np.full(N, 1.0 / N), NP=np.zeros(N), C=np.zeros(N),
            N=N, num_steps=3.0, steps=0.0, b=0.85)
    if kind == 1:
        m = 300 + 16 * (i % 5)
        return "group_by", dict(
            S=(rng.integers(0, 16, m).astype(np.float64),
               rng.standard_normal(m)), C=np.zeros(16))
    m, K = 100 + 8 * (i % 5), 4
    return "kmeans_step", dict(
        P=(rng.standard_normal(m) * 3, rng.standard_normal(m) * 3),
        CX=rng.standard_normal(K), CY=rng.standard_normal(K), K=K,
        D=np.zeros((m, K)), MinD=np.full(m, 1e30), Cl=np.zeros(m),
        SX=np.zeros(K), SY=np.zeros(K), CN=np.zeros(K),
        NX=np.zeros(K), NY=np.zeros(K))


async def client(server: PlanServer, i: int, n_requests: int):
    for _ in range(n_requests):
        name, inputs = request_for(i)
        out = await server.arun(name, inputs)
        assert all(np.all(np.isfinite(v)) for v in out.values())


def main():
    print("compiling the workload programs...")
    server = PlanServer({
        "pagerank": compile_program(pagerank),
        "group_by": compile_program(group_by),
        "kmeans_step": compile_program(kmeans_step),
    }, max_batch=8, flush_ms=2.0)
    server.start()                      # pump thread: batches + dispatches

    async def drive():
        await asyncio.gather(*(client(server, i, 4) for i in range(24)))

    try:
        print("serving 24 concurrent clients x 4 requests each...")
        asyncio.run(asyncio.wait_for(drive(), timeout=120))
    finally:
        server.stop()
    print()
    print(server.explain_serving())


if __name__ == "__main__":
    main()
